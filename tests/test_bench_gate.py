"""Benchmark regression gate: the CI tripwire must actually trip.

The gate's whole value is failing PRs on injected regressions — these tests
inject them: grown wire bytes (any growth fails), a >25% slowdown, a >25%
rate drop, and a metric that silently disappeared. Within-budget noise and
improvements must pass (improvements surface as refresh-the-baseline notes).
"""
import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.bench_gate import (compare, main, render_markdown,  # noqa: E402
                                   summary_rows)


@pytest.fixture(autouse=True)
def _no_ambient_step_summary(monkeypatch):
    """CI sets $GITHUB_STEP_SUMMARY for every step — including this pytest
    run. Tests drive the summary through an explicit --summary path, never
    the ambient file."""
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)


BASE = {
    "bench": "codec_sweep",
    "metrics": {
        "default/wire_bytes": {"value": 20750, "kind": "bytes"},
        "default/encode_ms": {"value": 1.2, "kind": "time"},
        "engine/speedup": {"value": 2.0, "kind": "rate"},
        "parity": {"value": 1, "kind": "info"},
    },
}


def _with(key, value):
    cur = copy.deepcopy(BASE)
    cur["metrics"][key]["value"] = value
    return cur


def test_identical_snapshots_pass():
    failures, notes = compare(BASE, copy.deepcopy(BASE))
    assert failures == [] and notes == []


def test_injected_byte_growth_fails():
    failures, _ = compare(BASE, _with("default/wire_bytes", 20751))
    assert len(failures) == 1 and "wire bytes grew" in failures[0]


def test_byte_improvement_passes_with_note():
    failures, notes = compare(BASE, _with("default/wire_bytes", 20000))
    assert failures == []
    assert any("refresh the baseline" in n for n in notes)


def test_injected_slowdown_fails():
    """The acceptance demo: an encode-time regression past the relative
    budget AND the 1 ms absolute slack fails the gate."""
    failures, _ = compare(BASE, _with("default/encode_ms", 3.2))
    assert len(failures) == 1 and "time regressed" in failures[0]


def test_slowdown_within_budget_passes():
    failures, _ = compare(BASE, _with("default/encode_ms", 1.2 * 1.2))
    assert failures == []


def test_ms_jitter_within_absolute_slack_passes():
    """Sub-ms timings flap >25% from scheduler jitter alone on a 2-core
    runner: a delta under the absolute ms slack passes even when the
    relative budget is blown — and fails once the slack is disabled."""
    cur = _with("default/encode_ms", 1.2 * 1.6)      # +55%, delta 0.72 ms
    failures, _ = compare(BASE, cur)
    assert failures == []
    failures, _ = compare(BASE, cur, ms_slack=0.0)
    assert len(failures) == 1 and "time regressed" in failures[0]


def test_seconds_scale_time_metrics_get_no_slack():
    """The slack keys off the *_ms suffix: a seconds-scale round time is
    far above the jitter floor, so the pure relative budget applies."""
    base = copy.deepcopy(BASE)
    base["metrics"]["scale/round_s"] = {"value": 2.5, "kind": "time"}
    cur = copy.deepcopy(base)
    cur["metrics"]["scale/round_s"]["value"] = 2.5 * 1.3
    failures, _ = compare(base, cur)
    assert len(failures) == 1 and "round_s" in failures[0]


def test_rate_drop_fails_but_info_is_never_gated():
    failures, _ = compare(BASE, _with("engine/speedup", 1.0))
    assert len(failures) == 1 and "rate regressed" in failures[0]
    failures, _ = compare(BASE, _with("parity", 0))
    assert failures == []


def test_missing_metric_fails():
    cur = copy.deepcopy(BASE)
    del cur["metrics"]["default/wire_bytes"]
    failures, _ = compare(BASE, cur)
    assert len(failures) == 1 and "disappeared" in failures[0]


def test_new_metric_noted_not_gated():
    cur = copy.deepcopy(BASE)
    cur["metrics"]["brand_new"] = {"value": 1, "kind": "bytes"}
    failures, notes = compare(BASE, cur)
    assert failures == []
    assert any("new metric" in n for n in notes)


def test_tolerance_override():
    failures, _ = compare(BASE, _with("default/encode_ms", 1.2 * 1.5),
                          tolerance=0.75)
    assert failures == []


@pytest.mark.parametrize("inject,code", [(None, 0), (30000, 1)])
def test_main_end_to_end(tmp_path, inject, code):
    """The CLI the workflow runs: exit 0 on parity, 1 on regression, and a
    missing current snapshot also fails."""
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir()
    cdir.mkdir()
    (bdir / "BENCH_codec_sweep.json").write_text(json.dumps(BASE))
    cur = BASE if inject is None else _with("default/wire_bytes", inject)
    (cdir / "BENCH_codec_sweep.json").write_text(json.dumps(cur))
    assert main(["--baseline", str(bdir), "--current", str(cdir)]) == code


def test_main_missing_snapshot_fails(tmp_path):
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir()
    cdir.mkdir()
    (bdir / "BENCH_codec_sweep.json").write_text(json.dumps(BASE))
    assert main(["--baseline", str(bdir), "--current", str(cdir)]) == 1


def test_main_no_baselines_is_an_error(tmp_path):
    assert main(["--baseline", str(tmp_path), "--current",
                 str(tmp_path)]) == 2


# ---------------------------------------------------------------------------
# perf-trend summary table ($GITHUB_STEP_SUMMARY)
# ---------------------------------------------------------------------------

def test_summary_rows_deltas():
    cur = _with("default/wire_bytes", 20000)      # -3.6%
    rows = {(r[0], r[1]): r for r in summary_rows(BASE, cur)}
    name, key, kind, bv, cv, delta = rows[("codec_sweep",
                                           "default/wire_bytes")]
    assert (kind, bv, cv) == ("bytes", 20750, 20000)
    assert delta == pytest.approx(-3.614, abs=1e-3)
    # unchanged metric: delta 0
    assert rows[("codec_sweep", "engine/speedup")][5] == pytest.approx(0.0)


def test_summary_rows_handle_one_sided_metrics():
    cur = copy.deepcopy(BASE)
    del cur["metrics"]["engine/speedup"]          # disappeared
    cur["metrics"]["brand_new"] = {"value": 5, "kind": "rate"}
    rows = {(r[0], r[1]): r for r in summary_rows(BASE, cur)}
    assert rows[("codec_sweep", "engine/speedup")][4] is None   # no current
    assert rows[("codec_sweep", "brand_new")][3] is None        # no baseline
    assert rows[("codec_sweep", "brand_new")][5] is None        # no delta


def test_render_markdown_table_shape():
    md = render_markdown(summary_rows(BASE, _with("default/encode_ms", 1.5)))
    lines = md.splitlines()
    assert lines[2].startswith("| bench | metric | kind | baseline "
                               "| current | delta % |")
    row = next(ln for ln in lines if "default/encode_ms" in ln)
    assert "| 1.2 | 1.5 | +25.0% |" in row
    # one table row per metric
    assert sum(ln.startswith("| codec_sweep |") for ln in lines) \
        == len(BASE["metrics"])


def test_main_appends_step_summary(tmp_path):
    """The CI wiring: --summary (defaulted from $GITHUB_STEP_SUMMARY)
    APPENDS the trend table — regression runs included, because the table
    is exactly the evidence a red gate needs."""
    bdir, cdir = tmp_path / "base", tmp_path / "cur"
    bdir.mkdir()
    cdir.mkdir()
    (bdir / "BENCH_codec_sweep.json").write_text(json.dumps(BASE))
    (cdir / "BENCH_codec_sweep.json").write_text(
        json.dumps(_with("default/wire_bytes", 30000)))
    summary = tmp_path / "summary.md"
    summary.write_text("pre-existing step output\n")
    assert main(["--baseline", str(bdir), "--current", str(cdir),
                 "--summary", str(summary)]) == 1
    text = summary.read_text()
    assert text.startswith("pre-existing step output\n")
    assert "| codec_sweep | default/wire_bytes | bytes | 20750 | 30000 " \
        in text
