"""Device-resident round loop (ISSUE 10, DESIGN.md §14).

The contract under test: with ``backend="pallas"`` the batched uplink keeps
residual shards on device between rounds, crossing the host boundary exactly
ONCE per round — the counted ``ops.host_fetch`` that carries the wire
payload — while staying byte-identical (ledger, per-round, global state) to
the non-resident path. Plus the encode-overlap staging: ``overlap_encode``
must be bitwise invisible whether staged encodes hit or miss.

CPU note: interpret mode routes the resident entry points through the same
numpy fallbacks as the non-resident path, so "byte-identical" here is exact
equality, and the host-fetch counter counts the same sanctioned crossings
the TPU build makes.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.codec import CodecConfig, CodecSpec
from repro.core.sparsify import AdaptiveSparsifier, SparsifyConfig
from repro.data.synthetic import TaskConfig
from repro.fed.service import FederationService, ServiceConfig
from repro.fed.strategies import EcoLoRAConfig
from repro.fed.trainer import FedConfig, FederatedTrainer

CFG = get_config("llama2-7b").reduced()
TC = TaskConfig(vocab_size=128, seq_len=16, n_samples=256, seed=0)
INT8_UP = CodecConfig(uplink=CodecSpec(quantize="int8"))


def _make(rounds=3, **kw):
    fed = FedConfig(method="fedit", n_clients=8, clients_per_round=4,
                    rounds=rounds, local_steps=2, local_batch=4, lr=3e-3,
                    eco=EcoLoRAConfig(n_segments=2,
                                      sparsify=SparsifyConfig()),
                    pretrain_steps=5, engine="batched", **kw)
    return FederatedTrainer(CFG, fed, TC)


def _assert_bitwise(a, b, logs=True):
    led_a, led_b = a.server.ledger, b.server.ledger
    assert led_a.upload_bytes == led_b.upload_bytes
    assert led_a.download_bytes == led_b.download_bytes
    assert led_a.upload_params == led_b.upload_params
    if logs:                   # resumed runs only log post-resume rounds
        for la, lb in zip(a.logs, b.logs):
            assert (la.upload_bytes, la.download_bytes) \
                == (lb.upload_bytes, lb.download_bytes), la.round_t
    np.testing.assert_array_equal(a.server.global_vec, b.server.global_vec)
    np.testing.assert_array_equal(a.server.last_broadcast,
                                  b.server.last_broadcast)


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_device_resident_requires_pallas():
    with pytest.raises(ValueError, match="requires backend='pallas'"):
        FedConfig(device_resident=True, backend="numpy")


def test_resident_resolution_follows_backend():
    """device_resident=None resolves to the backend: on for pallas, off
    for numpy; an explicit False opts a pallas run out."""
    assert _make(backend="pallas").protocol.resident
    assert not _make(backend="numpy").protocol.resident
    assert not _make(backend="pallas",
                     device_resident=False).protocol.resident


# ---------------------------------------------------------------------------
# parity: residency must be byte-invisible
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", [None, INT8_UP],
                         ids=["fp16-default", "int8-uplink"])
def test_resident_bitwise_parity_with_non_resident(codec):
    a = _make(backend="pallas", device_resident=False, codec=codec)
    b = _make(backend="pallas", device_resident=True, codec=codec)
    a.run()
    b.run()
    _assert_bitwise(a, b)


# ---------------------------------------------------------------------------
# the one sanctioned host crossing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", [None, INT8_UP],
                         ids=["fp16-default", "int8-uplink"])
def test_exactly_one_host_fetch_per_round(codec):
    """The device-residency contract (DESIGN.md §14): one counted
    device->host transfer per round — the codes/values + scales that go on
    the wire — regardless of value stage."""
    from repro.kernels import ops
    rounds = 4
    tr = _make(rounds=rounds, backend="pallas", codec=codec)
    c0 = ops.host_fetch_count()
    tr.run()
    assert ops.host_fetch_count() - c0 == rounds


def test_non_resident_pallas_makes_no_counted_fetches():
    """The counter measures the RESIDENT path's sanctioned crossing only:
    the legacy pallas path materialises through np.asarray instead, so the
    counter isolates the new contract."""
    from repro.kernels import ops
    tr = _make(backend="pallas", device_resident=False)
    c0 = ops.host_fetch_count()
    tr.run()
    assert ops.host_fetch_count() - c0 == 0


# ---------------------------------------------------------------------------
# lifecycle transitions drain device state
# ---------------------------------------------------------------------------

def test_checkpoint_resume_parity_under_residency(tmp_path):
    """state() drains device shards to host arrays (the sanctioned
    lifecycle-transition crossing), so a mid-run checkpoint + resume stays
    bitwise an uninterrupted resident run."""
    from repro.checkpoint import ckpt
    full = _make(backend="pallas")
    full.run()

    first = _make(backend="pallas")
    first.run(rounds=2)
    p = str(tmp_path / "resident.ckpt")
    ckpt.save_fed_state(p, first)
    resumed = _make(backend="pallas")
    assert ckpt.load_fed_state(p, resumed) == 2
    resumed.run()
    _assert_bitwise(full, resumed, logs=False)


def test_device_shard_drain_semantics():
    """Unit contract of the device-shard store: device handles are
    authoritative until a host read drains them (writable copies), and
    restore() re-anchors on host state."""
    sp = AdaptiveSparsifier(SparsifyConfig(), np.arange(10) % 2 == 0)
    dev = np.arange(4, dtype=np.float32)        # stands in for a handle
    sp.put_device_shard(0, 4, dev)
    assert sp.device_shard(0, 4) is dev
    assert sp.residual_nbytes() == 16           # counted without draining
    assert sp._device_shards                    # ...still resident
    drained = sp.residual_shard(0, 4)           # host read drains the span
    np.testing.assert_array_equal(drained, dev)
    assert not sp._device_shards
    drained[0] = 99.0                           # writable copy, not a view
    assert dev[0] == 0.0
    # a fresh device handle supersedes the host shard...
    sp.put_device_shard(0, 4, np.full(4, 7, np.float32))
    np.testing.assert_array_equal(sp.residual, np.array(
        [7, 7, 7, 7, 0, 0, 0, 0, 0, 0], np.float32))
    assert not sp._device_shards                # .residual drains everything


# ---------------------------------------------------------------------------
# encode-overlap staging
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eval_every", [1, 3],
                         ids=["eval-every-round", "sparse-eval"])
def test_overlap_encode_bitwise_parity(eval_every):
    """overlap_encode on vs off: bitwise identical ledgers and state. With
    eval every round the staged encode always misses (observe_global_loss
    moves the adaptive schedule); with sparse eval it hits — both paths
    must be invisible on the wire."""
    a = _make(rounds=6, eval_every=eval_every)
    b = _make(rounds=6, eval_every=eval_every)
    FederationService(a, ServiceConfig()).run()
    FederationService(b, ServiceConfig(overlap_encode=True)).run()
    _assert_bitwise(a, b)
    if eval_every == 1:
        assert b.server._staged_hits == 0
    else:
        assert b.server._staged_hits > 0


def test_stage_broadcast_invalidated_by_state_changes():
    """A staged encode is only adopted when its inputs are provably what
    begin_round sees: a schedule move (observe_global_loss) or a base
    re-anchor invalidates it and begin_round encodes synchronously."""
    tr = _make()
    srv = tr.server
    tr.run(rounds=1)
    t = srv.round_t
    srv.stage_broadcast(t)
    srv.observe_global_loss(0.5)       # moves the adaptive schedule
    srv.begin_round(t)
    assert srv._staged_hits == 0
    t = srv.round_t                     # begin_round left round_t at t
    srv.stage_broadcast(t)
    srv.begin_round(t)                  # nothing changed: adopt
    assert srv._staged_hits == 1
