"""Protocol/Endpoint/Transport API: driving ServerEndpoint + ClientRuntime
manually over InMemoryTransport reproduces FederatedTrainer.run() bitwise
(global_vec, wire bytes, per-round ledger diffs) — the facade-vs-trainer
ledger divergence (the old fed.server.Server never billed broadcast
catch-up downloads) is structurally gone: there is one implementation.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparsify import SparsifyConfig
from repro.data.synthetic import TaskConfig
from repro.fed.strategies import EcoLoRAConfig, make_policy
from repro.fed.trainer import FedConfig, FederatedTrainer

CFG = get_config("llama2-7b").reduced()
TC = TaskConfig(vocab_size=128, seq_len=16, n_samples=256, seed=0)
ROUNDS = 3


def _make_trainer(method, engine, backend="numpy", **kw):
    fed = FedConfig(method=method, n_clients=8, clients_per_round=4,
                    rounds=ROUNDS, local_steps=2, local_batch=4, lr=3e-3,
                    eco=EcoLoRAConfig(n_segments=2, sparsify=SparsifyConfig()),
                    pretrain_steps=5, engine=engine, backend=backend, **kw)
    return FederatedTrainer(CFG, fed, TC)


def _drive_via_message_api(tr, rounds):
    """Replicate the round loop through ONLY the public endpoint/transport
    message API (what an external deployment would write)."""
    srv, cl, tp = tr.server, tr.clients, tr.transport
    per_round = []
    for t in range(rounds):
        sampled = tr.sampler.sample(t)
        participants = tp.plan_round(t, sampled)
        up0, down0 = srv.ledger.upload_bytes, srv.ledger.download_bytes
        tp.on_broadcast(srv.begin_round(t))
        for cid in participants:
            dl = srv.sync_client(int(cid), t)
            tp.on_download(dl)
            cl.apply_download(int(cid), dl)
        msgs, compute_s = cl.run_round(t, participants)
        for msg in tp.dispatch_uploads(t, msgs, compute_s):
            srv.receive(msg)
        updates = srv.end_round(t)
        if tr.policy.merges_into_base:
            tr._flora_merge_and_reinit(t, participants, updates)
        tp.finish_round(t)
        gloss, _ = tr.evaluate(srv.global_vec)
        tr.observe_global_loss(gloss)
        srv.snapshot(t)
        per_round.append((srv.ledger.upload_bytes - up0,
                          srv.ledger.download_bytes - down0))
    return per_round


def _assert_bitwise_parity(a, b, manual_rounds):
    """a: trainer driven by run(); b: trainer driven via the message API."""
    np.testing.assert_array_equal(a.server.global_vec, b.server.global_vec)
    led_a, led_b = a.server.ledger, b.server.ledger
    assert led_a.upload_bytes == led_b.upload_bytes
    assert led_a.download_bytes == led_b.download_bytes
    assert led_a.upload_params == led_b.upload_params
    assert led_a.download_params == led_b.download_params
    for lg, (up, down) in zip(a.logs, manual_rounds):
        assert lg.upload_bytes == up, lg.round_t
        assert lg.download_bytes == down, lg.round_t
    np.testing.assert_array_equal(a.clients.views, b.clients.views)


def test_message_api_parity_quick():
    """One non-slow config: fedit, batched engine."""
    a = _make_trainer("fedit", "batched")
    b = _make_trainer("fedit", "batched")
    a.run()
    rounds = _drive_via_message_api(b, ROUNDS)
    _assert_bitwise_parity(a, b, rounds)


@pytest.mark.slow
@pytest.mark.parametrize("method,engine", [
    ("fedit", "serial"),
    ("ffa_lora", "serial"),
    ("ffa_lora", "batched"),
    ("flora", "serial"),
    ("flora", "batched"),
])
def test_message_api_parity(method, engine):
    a = _make_trainer(method, engine)
    b = _make_trainer(method, engine)
    a.run()
    rounds = _drive_via_message_api(b, ROUNDS)
    _assert_bitwise_parity(a, b, rounds)


def test_download_billing_not_undercounted():
    """Regression for the old Server facade: a full round over the message
    API must bill downloads (broadcast catch-up), not just uploads."""
    tr = _make_trainer("fedit", "batched")
    rounds = _drive_via_message_api(tr, 2)
    for up, down in rounds:
        assert up > 0 and down > 0
    # every participant paid for every broadcast so far: round 0 bills
    # K one-packet catch-ups, round 1 at least as many packets again
    assert tr.server.ledger.download_params > 0


# ---------------------------------------------------------------------------
# client-state store parity (ISSUE 3 tentpole): the O(active) COW store must
# be byte-identical on the wire and bitwise on global_vec vs the dense store
# ---------------------------------------------------------------------------

def test_state_store_cow_vs_dense_bitwise():
    a = _make_trainer("fedit", "batched", state_store="cow")
    b = _make_trainer("fedit", "batched", state_store="dense")
    a.run()
    b.run()
    np.testing.assert_array_equal(a.server.global_vec, b.server.global_vec)
    led_a, led_b = a.server.ledger, b.server.ledger
    assert led_a.upload_bytes == led_b.upload_bytes
    assert led_a.download_bytes == led_b.download_bytes
    assert led_a.upload_params == led_b.upload_params
    assert led_a.download_params == led_b.download_params
    for la, lb in zip(a.logs, b.logs):
        assert la.upload_bytes == lb.upload_bytes, la.round_t
        assert la.download_bytes == lb.download_bytes, la.round_t
    # identical dense materialisation, at a fraction of the memory
    np.testing.assert_array_equal(a.clients.views, b.clients.views)
    assert a.clients.view_store.nbytes() < b.clients.view_store.nbytes()


def test_cow_store_tracks_dense_shadow():
    """Every round the COW store's materialisation must equal a dense shadow
    maintained directly from the DownloadMsgs (the store is pure
    bookkeeping — it may never change what a client would train from)."""
    tr = _make_trainer("fedit", "batched")
    srv, cl, tp = tr.server, tr.clients, tr.transport
    shadow = cl.views.copy()
    for t in range(ROUNDS):
        participants = tp.plan_round(t, tr.sampler.sample(t))
        tp.on_broadcast(srv.begin_round(t))
        for cid in participants:
            dl = srv.sync_client(int(cid), t)
            tp.on_download(dl)
            cl.apply_download(int(cid), dl)
            shadow[int(cid)] = dl.view
        msgs, compute_s = cl.run_round(t, participants)
        for msg in tp.dispatch_uploads(t, msgs, compute_s):
            srv.receive(msg)
        srv.end_round(t)
        np.testing.assert_array_equal(cl.views, shadow)
    # only the sampled participants ever deviate from the shared base
    assert cl.view_store.n_deviations() <= ROUNDS * tr.fed.clients_per_round


# ---------------------------------------------------------------------------
# FLoRA server-side vector cache: merge-on-evict LRU (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def _flora_updates(round_t, cids, size, n_segments=2, val=1.0):
    from repro.core.segments import SegmentUpdate, segment_bounds, segment_id
    ups = []
    for cid in cids:
        seg = segment_id(cid, round_t, n_segments)
        s, e = segment_bounds(size, n_segments)[seg]
        ups.append(SegmentUpdate(cid, round_t, seg,
                                 np.full(e - s, val, np.float32), 10, 1.0))
    return ups


def test_flora_server_vecs_bounded_with_merge_on_evict():
    """A long-lived FLoRA server that never clears (a custom driver / the
    ROADMAP's large-population concern) stays O(cap) in per-client vectors;
    evicted vectors fold into the stacked aggregate so NO update mass is
    lost, and the broadcastable weighted average (which only reads the
    current round's participants) matches the uncapped policy bitwise."""
    from repro.fed.strategies import FLoRAPolicy

    size, ns, k = 64, 2, 2
    capped = FLoRAPolicy(server_vec_cap=4)
    free = FLoRAPolicy()
    gv = np.zeros(size, np.float32)
    # 10 rounds x 2 fresh participants each: 20 distinct clients, none
    # returning after eviction (a returning evicted client legitimately
    # restarts from zero — its history lives in the folded aggregate)
    for t in range(10):
        cids = [2 * t, 2 * t + 1]
        ups = _flora_updates(t, cids, size, ns, val=float(t + 1))
        out_c = capped.aggregate(t, ups, gv, ns)
        out_f = free.aggregate(t, ups, gv, ns)
        np.testing.assert_array_equal(out_c, out_f)   # broadcast unchanged
        assert len(capped.server_client_vecs) <= 4
    assert len(free.server_client_vecs) == 20         # the unbounded growth
    assert capped.evicted_count == 20 - len(capped.server_client_vecs)
    # conservation: retained + folded == everything ever uploaded
    total_c = sum(capped.server_client_vecs.values()) + capped.evicted_vec
    total_f = sum(free.server_client_vecs.values())
    np.testing.assert_allclose(total_c, total_f)
    assert capped.evicted_samples == 10 * (20 - len(capped.server_client_vecs))
    assert capped.cache_nbytes() < free.cache_nbytes()


def test_flora_lru_never_evicts_current_round_participants():
    """A buffered-async straggler can push one round's DISTINCT updaters
    above the cap; the LRU must soft-exceed rather than evict a vector the
    weighted average / merge still reads (regression: KeyError)."""
    from repro.fed.strategies import FLoRAPolicy

    size, ns = 64, 2
    pol = FLoRAPolicy(server_vec_cap=2)
    gv = np.zeros(size, np.float32)
    # round 1 delivers 2 on-time updates + 1 straggler from round 0:
    # 3 distinct participants against cap=2
    ups = _flora_updates(1, [1, 2], size, ns) + \
        _flora_updates(0, [3], size, ns)
    out = pol.aggregate(1, ups, gv, ns)          # must not raise
    assert np.isfinite(out).all()
    assert set(pol.server_client_vecs) == {1, 2, 3}   # soft-exceeded
    # next round: all three are evictable again, the cap re-applies
    pol.aggregate(2, _flora_updates(2, [4, 5], size, ns), gv, ns)
    assert len(pol.server_client_vecs) == 2
    assert pol.evicted_count == 3


def _toy_product_fn(size=64, ra=4, rk=4):
    """LoRA-pair-shaped product for a policy-level test: the first ra*rk
    entries are A (ra x rk), the next rk*(size//rk - ra)... keep it simple:
    A = vec[:16].reshape(4, 4), B = vec[16:48].reshape(4, 8), product =
    A @ B flattened — bilinear, like the real scale*(a@b) merge."""
    def fn(vec):
        a = vec[:16].reshape(4, 4)
        b = vec[16:48].reshape(4, 8)
        return (a @ b).reshape(-1).astype(np.float32)
    return fn


def test_flora_exact_merge_on_evict_conserves_product():
    """ISSUE 5 / ROADMAP fix: eviction folds the merged a@b PRODUCT, not
    the raw stacked vector. The stacking-aggregation invariant — the
    sample-weighted sum of per-client products — must match an uncapped
    server exactly; the legacy vector fold provably cannot (the product of
    a sum is not the sum of products)."""
    from repro.fed.strategies import FLoRAPolicy

    size, ns = 64, 2
    fn = _toy_product_fn()
    capped = FLoRAPolicy(server_vec_cap=4, product_fn=fn)
    legacy = FLoRAPolicy(server_vec_cap=4)          # old stacked fold
    free = FLoRAPolicy()
    gv = np.zeros(size, np.float32)
    rng = np.random.default_rng(0)
    all_updates = {}
    for t in range(10):
        cids = [2 * t, 2 * t + 1]
        ups = _flora_updates(t, cids, size, ns, val=float(rng.normal()))
        for pol in (capped, legacy, free):
            pol.aggregate(t, [type(u)(u.client_id, u.round_t, u.seg_id,
                                      u.values.copy(), u.num_samples,
                                      u.local_loss) for u in ups], gv, ns)
        all_updates[t] = cids
    assert capped.evicted_count > 0
    assert capped.evicted_vec is None               # no legacy fold anymore

    def total_product(pol):
        tot = np.zeros(32, np.float32)
        for cid, vec in pol.server_client_vecs.items():
            tot += pol._last_samples[cid] * fn(vec)
        if pol.evicted_product is not None:
            tot += pol.evicted_product
        return tot

    exact = total_product(free)                     # ground truth: no evict
    np.testing.assert_allclose(total_product(capped), exact,
                               rtol=1e-5, atol=1e-5)
    # the legacy fold loses the product structure: applying the product to
    # the folded vector does NOT reconstruct the per-client product sum
    legacy_total = np.zeros(32, np.float32)
    for cid, vec in legacy.server_client_vecs.items():
        legacy_total += legacy._last_samples[cid] * fn(vec)
    # (weight the fold by its average sample mass — the best a vector fold
    # can do)
    legacy_total += (legacy.evicted_samples / max(legacy.evicted_count, 1)
                     ) * fn(legacy.evicted_vec)
    assert not np.allclose(legacy_total, exact, rtol=1e-3)


def test_flora_trainer_wires_exact_product_fn():
    """The trainer supplies the policy a real product_fn (scale * a@b over
    the protocol's LoRA pairs) — bilinear in the vector halves and shaped
    like the merged delta."""
    tr = _make_trainer("flora", "batched", flora_server_vec_cap=4)
    fn = tr.policy.product_fn
    assert fn is not None
    rng = np.random.default_rng(1)
    v = rng.standard_normal(tr.protocol.size).astype(np.float32)
    p = fn(v)
    assert p.dtype == np.float32 and p.size > 0 and np.isfinite(p).all()
    # bilinearity in the A half: doubling A (with B fixed at v's B) adds
    # exactly one more product of the original
    from repro.core.sparsify import ab_mask_from_spec
    ab = ab_mask_from_spec(tr.protocol.spec)
    v2 = v.copy()
    v2[ab] *= 2.0
    np.testing.assert_allclose(fn(v2), 2.0 * p, rtol=1e-5, atol=1e-6)


def test_flora_lru_state_survives_checkpoint(tmp_path):
    """LRU (insertion) order, per-client sample weights, and the folded
    aggregate round-trip through save/load — a resumed capped server must
    evict exactly what an uninterrupted one would."""
    from repro.checkpoint import ckpt

    tr = _make_trainer("flora", "batched", flora_server_vec_cap=4)
    pol = tr.policy
    size = tr.protocol.size
    rng = np.random.default_rng(0)
    # seed policy state in a deliberately non-sorted LRU order
    for cid in (7, 2, 5):
        pol.server_client_vecs[cid] = rng.standard_normal(size) \
            .astype(np.float32)
        pol._last_samples[cid] = 10 * cid
    pol.evicted_vec = rng.standard_normal(size).astype(np.float32)
    pol.evicted_samples, pol.evicted_count = 30, 3
    p = str(tmp_path / "flora.ckpt")
    ckpt.save_fed_state(p, tr)

    tr2 = _make_trainer("flora", "batched", flora_server_vec_cap=4)
    ckpt.load_fed_state(p, tr2)
    pol2 = tr2.policy
    assert list(pol2.server_client_vecs) == [7, 2, 5]   # LRU order kept
    for cid in (7, 2, 5):
        np.testing.assert_array_equal(pol2.server_client_vecs[cid],
                                      pol.server_client_vecs[cid])
    assert pol2._last_samples == {7: 70, 2: 20, 5: 50}
    np.testing.assert_array_equal(pol2.evicted_vec, pol.evicted_vec)
    assert (pol2.evicted_samples, pol2.evicted_count) == (30, 3)


def test_flora_trainer_with_cap_matches_uncapped():
    """End-to-end: with cap >= clients_per_round the standard driver
    (which clears per round) is bitwise unaffected by the LRU."""
    a = _make_trainer("flora", "batched")
    b = _make_trainer("flora", "batched", flora_server_vec_cap=4)
    a.run()
    b.run()
    np.testing.assert_array_equal(a.server.global_vec, b.server.global_vec)
    assert a.server.ledger.total_bytes == b.server.ledger.total_bytes


# ---------------------------------------------------------------------------
# config validation (satellite: make_strategy KeyError -> ValueError)
# ---------------------------------------------------------------------------

def test_make_policy_unknown_method():
    with pytest.raises(ValueError, match="fedit"):
        make_policy("fedavg_typo")


@pytest.mark.parametrize("kw", [
    {"method": "fed_it"},
    {"partition": "iid"},
    {"engine": "threaded"},
    {"backend": "cuda"},
    {"sampler": "round_robin"},
    {"state_store": "sparse_matrix"},
])
def test_fed_config_validation(kw):
    with pytest.raises(ValueError, match="unknown"):
        FedConfig(**kw)


def test_fed_config_valid_values_pass():
    for m in ("fedit", "ffa_lora", "flora", "dpo"):
        FedConfig(method=m)
    for p in ("dirichlet", "task"):
        FedConfig(partition=p)
