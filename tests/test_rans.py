"""rANS coder edge cases (repro.core.rans): adversarial inputs that the
federated wire path never produces on the happy path — degenerate
single-symbol histograms, max-resolution tables, empty payloads, corrupt
model tables — plus the ``AnsValues`` never-expand bypass boundary and the
N-lane interleaved coder (ISSUE 10): lane-1 byte-parity with the scalar
format, exact round-trips across random streams/lane counts, and typed
errors on truncated/corrupted lane headers. The deterministic tests run on
a bare interpreter; the hypothesis property tests skip without it."""
import numpy as np
import pytest

from repro.core import rans
from repro.core.codec import (AnsValues, Carrier, CodecSpec, Section,
                              build_pipeline, decode_packet)
from repro.core.sparsify import SparsifyConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # bare-interpreter CI job
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# model resolution schedule
# ---------------------------------------------------------------------------

def test_scale_bits_for_pins():
    """The adaptive table resolution: floor 9 bits, one bit per doubling,
    ceiling 12 at count >= 4096. Changing this silently re-prices every ANS
    packet on the wire."""
    for count, bits in [(0, 9), (1, 9), (511, 9), (512, 9), (1023, 9),
                        (1024, 10), (2047, 10), (2048, 11), (4095, 11),
                        (4096, 12), (1 << 20, 12)]:
        assert rans.scale_bits_for(count) == bits, (count, bits)


# ---------------------------------------------------------------------------
# degenerate histograms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [1, 5, 10_000])
def test_single_symbol_stream_round_trips(length):
    """A one-symbol alphabet is the coder's degenerate extreme: the whole
    probability mass sits on one slot, every encode step is pure renorm.
    Must round-trip at any length (including the 12-bit table regime)."""
    symbols = np.full(length, 7, np.int64)
    stream, model, bits = rans.encode_bytes(symbols)
    assert bits == rans.scale_bits_for(length)
    out = rans.decode_bytes(stream, model, length, bits)
    np.testing.assert_array_equal(out, symbols)
    # the entropy of a constant stream is zero: the coder should spend
    # (almost) nothing beyond the flushed state + packed model
    assert len(stream) <= rans._STATE_BYTES + 2


def test_single_symbol_normalized_table_holds_full_mass():
    freqs = rans.normalize_freqs(np.bincount([3] * 10, minlength=8), 9)
    assert int(freqs.sum()) == 1 << 9
    assert freqs[3] == 1 << 9 and (freqs[np.arange(8) != 3] == 0).all()


def test_max_resolution_table_round_trips():
    """Full 256-symbol alphabet at the 12-bit resolution ceiling (count >=
    4096) — every slot table entry in play."""
    rng = np.random.default_rng(0xA45)
    symbols = rng.integers(0, 256, size=8192).astype(np.int64)
    stream, model, bits = rans.encode_bytes(symbols)
    assert bits == rans.MAX_SCALE_BITS
    out = rans.decode_bytes(stream, model, symbols.size, bits)
    np.testing.assert_array_equal(out, symbols)


def test_two_symbol_extreme_skew_round_trips():
    """A 9999:1 histogram quantizes the rare symbol to the freq-1 floor —
    the most mispriced model normalize_freqs can emit; the stream must
    still decode exactly."""
    symbols = np.zeros(10_000, np.int64)
    symbols[1234] = 255
    stream, model, bits = rans.encode_bytes(symbols)
    out = rans.decode_bytes(stream, model, symbols.size, bits)
    np.testing.assert_array_equal(out, symbols)


# ---------------------------------------------------------------------------
# empty payloads / impossible models
# ---------------------------------------------------------------------------

def test_empty_stream_has_no_model():
    with pytest.raises(ValueError, match="empty stream"):
        rans.normalize_freqs(np.zeros(256, np.int64), 12)
    with pytest.raises(ValueError, match="empty stream"):
        rans.encode_bytes(np.array([], np.int64))


def test_decode_zero_count_returns_empty():
    stream, model, bits = rans.encode_bytes(np.array([1, 2, 3], np.int64))
    out = rans.decode_bytes(stream, model, 0, bits)
    assert out.size == 0


def test_alphabet_too_large_for_resolution():
    """600 present symbols cannot all keep freq >= 1 in a 512-slot table."""
    with pytest.raises(ValueError, match="alphabet too large"):
        rans.normalize_freqs(np.ones(600, np.int64), 9)


def test_encode_rejects_zero_frequency_symbol():
    """A symbol absent from the model (freq 0) is unencodable — must raise
    up front, not corrupt the state machine."""
    freqs = rans.normalize_freqs(
        np.bincount([0, 0, 1, 1], minlength=4), 9)
    assert freqs[3] == 0
    with pytest.raises(ValueError, match="symbol 3 has zero model"):
        rans.encode(np.array([0, 1, 3], np.int64), freqs, 9)


def test_unpack_model_rejects_corruption():
    freqs = rans.normalize_freqs(
        np.bincount([0, 1, 1, 2], minlength=4), 9)
    blob = rans.pack_model(freqs)
    # wrong alphabet size
    with pytest.raises(ValueError, match="corrupt ANS model"):
        rans.unpack_model(blob, 8, 9)
    # wrong resolution: counts no longer sum to 1 << scale_bits
    with pytest.raises(ValueError, match="corrupt ANS model"):
        rans.unpack_model(blob, 4, 10)
    # tampered counts with the right shape
    bad = rans.pack_model(freqs + 1)
    with pytest.raises(ValueError, match="corrupt ANS model"):
        rans.unpack_model(bad, 4, 9)


# ---------------------------------------------------------------------------
# AnsValues never-expand bypass boundary
# ---------------------------------------------------------------------------

def _int8_ans_pipeline(n=4000):
    ab = np.arange(n) % 2 == 0
    pipe = build_pipeline(CodecSpec(sparsify="fixed", k=0.5,
                                    quantize="int8", entropy="ans"),
                          SparsifyConfig(), ab)
    pipe.observe_loss(1.0)
    return pipe


def test_ans_bypass_boundary_incompressible_values():
    """EXACTLY uniform int8 codes carry a full 8 bits/value of entropy:
    the rANS stream alone is ~the raw section and the packed model pushes
    it past — the stage must leave the values section UNTOUCHED (never
    expand), recording no ``ans`` meta."""
    codes = np.tile(np.arange(-128, 128, dtype=np.int8), 8)   # 2048 uniform
    car = Carrier(dense_size=codes.size, slice_=(0, codes.size), round_t=0)
    car.sections["values"] = Section(codes.copy(), 8 * codes.size)
    AnsValues().encode(car)
    assert "ans" not in car.meta, "uniform codes must take the raw bypass"
    assert "ans_model" not in car.sections
    np.testing.assert_array_equal(car.sections["values"].data, codes)


def test_ans_engages_on_skewed_values():
    """The complementary side of the boundary: heavily clustered values
    quantize to a handful of codes, the model+stream undercut the raw
    section, and the entropy-coded packet decodes to the SAME vector as
    the bypass would."""
    n = 4000
    pipe = _int8_ans_pipeline(n)
    rng = np.random.default_rng(2)
    values = rng.choice([-1.0, -0.5, 0.5, 1.0], n).astype(np.float32) \
        + rng.uniform(-1e-3, 1e-3, n).astype(np.float32)
    pkt = pipe.encode(values.copy(), 0)
    assert "ans" in pkt.meta and "ans_model" in pkt.sections
    kept = pkt.meta["ans"]["count"]
    wire_values = pkt.sections["values"].data.size \
        + pkt.sections["ans_model"].data.size
    assert wire_values < kept, (wire_values, kept)
    # parity with the plain int8 stack over the same input
    plain = build_pipeline(CodecSpec(sparsify="fixed", k=0.5,
                                     quantize="int8"),
                           SparsifyConfig(), np.arange(n) % 2 == 0)
    plain.observe_loss(1.0)
    pkt_plain = plain.encode(values.copy(), 0)
    np.testing.assert_array_equal(decode_packet(pkt),
                                  decode_packet(pkt_plain))


def test_ans_exact_boundary_is_never_worse():
    """Sweep stream sizes across the bypass threshold: whatever side a
    packet lands on, its billed values+model bytes never exceed the raw
    int8 section."""
    n = 2048
    ab = np.arange(n) % 2 == 0
    rng = np.random.default_rng(3)
    for mix in (0.0, 0.25, 0.5, 0.75, 1.0):   # uniform..clustered blend
        pipe = build_pipeline(CodecSpec(sparsify="fixed", k=0.5,
                                        quantize="int8", entropy="ans"),
                              SparsifyConfig(), ab)
        pipe.observe_loss(1.0)
        uniform = rng.uniform(-1, 1, n)
        clustered = rng.choice([-1.0, 1.0], n)
        values = ((1 - mix) * uniform + mix * clustered).astype(np.float32)
        pkt = pipe.encode(values.copy(), 0)
        raw_bytes = (pkt.meta["ans"]["count"] if "ans" in pkt.meta
                     else pkt.sections["values"].data.size)
        billed = pkt.sections["values"].data.size \
            + (pkt.sections["ans_model"].data.size
               if "ans_model" in pkt.sections else 0)
        assert billed <= raw_bytes, (mix, billed, raw_bytes)
        assert np.isfinite(decode_packet(pkt)).all()


# ---------------------------------------------------------------------------
# N-lane interleaved coder (ISSUE 10)
# ---------------------------------------------------------------------------

def _model_for(symbols, n_symbols=256):
    bits = rans.scale_bits_for(symbols.size)
    freqs = rans.normalize_freqs(
        np.bincount(symbols, minlength=n_symbols), bits)
    return freqs, bits


def test_lanes_for_schedule_pins():
    """The size->lane-count schedule is wire-adjacent configuration: quick
    CI packets (and every committed BENCH baseline) stay scalar, large
    packets take the full lane fan-out. Changing these thresholds re-prices
    streams, so they are pinned."""
    for count, lanes in [(0, 1), (1, 1), (8191, 1), (8192, 16),
                         (32767, 16), (32768, 64), (131071, 64),
                         (131072, 255), (1 << 20, 255)]:
        assert rans.lanes_for(count) == lanes, (count, lanes)
    assert rans.MAX_LANES == 255


def test_lane1_byte_identical_to_scalar():
    """Lane-count 1 IS the legacy format: same bytes, no header, so every
    existing checkpoint, ledger pin, and codec-sweep baseline stays
    valid."""
    rng = np.random.default_rng(0xEC0)
    for n in (1, 7, 100, 4096):
        symbols = np.clip(rng.normal(0, 20, n), -127, 127)\
            .astype(np.int64) + 128
        freqs, bits = _model_for(symbols)
        assert rans.encode_interleaved(symbols, freqs, bits, 1) \
            == rans.encode(symbols, freqs, bits)
        stream1, model1, bits1 = rans.encode_bytes(symbols, lanes=1)
        stream0, model0, bits0 = rans.encode_bytes(symbols)
        assert (stream1, model1, bits1) == (stream0, model0, bits0)


def test_multi_lane_stream_format_and_round_trip():
    """Multi-lane wire format: header byte = lane count, then 4 bytes of
    big-endian state per lane, then the interleaved body. Decodes exactly
    for lane counts that do and don't divide the stream length."""
    rng = np.random.default_rng(0xEC1)
    n = 10_001                       # deliberately not a lane multiple
    symbols = np.clip(rng.normal(0, 9, n), -127, 127).astype(np.int64) + 128
    freqs, bits = _model_for(symbols)
    for lanes in (2, 3, 16, 255):
        stream = rans.encode_interleaved(symbols, freqs, bits, lanes)
        assert stream[0] == lanes
        assert len(stream) >= 1 + rans._STATE_BYTES * lanes
        out = rans.decode_interleaved(stream, freqs, n, bits, lanes)
        np.testing.assert_array_equal(out, symbols)


def test_multi_lane_via_encode_bytes_meta_round_trip():
    """The codec-facing entry points carry the lane count out-of-band (the
    packet meta) AND in the stream header; both must agree on decode."""
    rng = np.random.default_rng(0xEC2)
    symbols = rng.integers(0, 64, size=9000).astype(np.int64)
    lanes = rans.lanes_for(symbols.size)
    assert lanes > 1
    stream, model, bits = rans.encode_bytes(symbols, lanes=lanes)
    out = rans.decode_bytes(stream, model, symbols.size, bits, lanes=lanes)
    np.testing.assert_array_equal(out, symbols)


def test_truncated_lane_stream_raises():
    symbols = np.arange(100, dtype=np.int64) % 7
    freqs, bits = _model_for(symbols, n_symbols=8)
    stream = rans.encode_interleaved(symbols, freqs, bits, 4)
    for cut in (0, 1, 1 + rans._STATE_BYTES * 4 - 1):
        with pytest.raises(ValueError, match="truncated ANS lane stream"):
            rans.decode_interleaved(stream[:cut], freqs, symbols.size,
                                    bits, 4)


def test_corrupt_lane_header_raises():
    """A stream whose embedded lane count disagrees with the metadata is
    corrupt — decoding with the wrong interleave order would emit garbage
    silently, so it must raise instead."""
    symbols = np.arange(100, dtype=np.int64) % 7
    freqs, bits = _model_for(symbols, n_symbols=8)
    stream = rans.encode_interleaved(symbols, freqs, bits, 4)
    tampered = bytes([2]) + stream[1:]
    with pytest.raises(ValueError, match="corrupt ANS lane header"):
        rans.decode_interleaved(tampered, freqs, symbols.size, bits, 4)


def test_ans_values_stage_records_lane_count():
    """End-to-end through the int8+ans pipeline: a large clustered stream
    engages the lane schedule, the packet meta records the lane count, and
    the decode matches the plain int8 stack exactly."""
    n = 60_000
    ab = np.arange(n) % 2 == 0
    rng = np.random.default_rng(0xEC3)
    values = rng.choice([-1.0, -0.5, 0.5, 1.0], n).astype(np.float32) \
        + rng.uniform(-1e-3, 1e-3, n).astype(np.float32)
    pipe = build_pipeline(CodecSpec(sparsify="fixed", k=0.5,
                                    quantize="int8", entropy="ans"),
                          SparsifyConfig(), ab)
    pipe.observe_loss(1.0)
    pkt = pipe.encode(values.copy(), 0)
    kept = pkt.meta["ans"]["count"]
    assert rans.lanes_for(kept) > 1
    assert pkt.meta["ans"]["lanes"] == rans.lanes_for(kept)
    plain = build_pipeline(CodecSpec(sparsify="fixed", k=0.5,
                                     quantize="int8"),
                           SparsifyConfig(), ab)
    plain.observe_loss(1.0)
    pkt_plain = plain.encode(values.copy(), 0)
    pkt.local.clear()               # force the wire decode, not the shortcut
    np.testing.assert_array_equal(decode_packet(pkt),
                                  decode_packet(pkt_plain))


@needs_hypothesis
@settings(max_examples=60, deadline=None) if HAVE_HYPOTHESIS else lambda f: f
@given(st.data()) if HAVE_HYPOTHESIS else lambda f: f
def test_interleaved_round_trip_property(data):
    """Any stream x any lane count round-trips exactly, and lane-count 1
    always matches the scalar coder byte-for-byte."""
    n = data.draw(st.integers(1, 400), label="n")
    alpha = data.draw(st.integers(1, 64), label="alphabet")
    lanes = data.draw(st.integers(1, 8), label="lanes")
    raw = data.draw(st.lists(st.integers(0, alpha - 1),
                             min_size=n, max_size=n), label="symbols")
    symbols = np.asarray(raw, np.int64)
    freqs, bits = _model_for(symbols, n_symbols=alpha)
    stream = rans.encode_interleaved(symbols, freqs, bits, lanes)
    if lanes == 1:
        assert stream == rans.encode(symbols, freqs, bits)
    out = rans.decode_interleaved(stream, freqs, n, bits, lanes)
    np.testing.assert_array_equal(out, symbols)


@needs_hypothesis
@settings(max_examples=40, deadline=None) if HAVE_HYPOTHESIS else lambda f: f
@given(st.data()) if HAVE_HYPOTHESIS else lambda f: f
def test_lane_stream_truncation_always_raises(data):
    """Cutting a multi-lane stream anywhere inside the header region
    raises the typed ValueError — never a silent wrong decode or an
    IndexError from the refill loop."""
    lanes = data.draw(st.integers(2, 8), label="lanes")
    symbols = np.asarray(data.draw(st.lists(st.integers(0, 7), min_size=32,
                                            max_size=128),
                                   label="symbols"), np.int64)
    freqs, bits = _model_for(symbols, n_symbols=8)
    stream = rans.encode_interleaved(symbols, freqs, bits, lanes)
    header = 1 + rans._STATE_BYTES * lanes
    cut = data.draw(st.integers(0, header - 1), label="cut")
    with pytest.raises(ValueError, match="truncated ANS lane stream"):
        rans.decode_interleaved(stream[:cut], freqs, symbols.size, bits,
                                lanes)
