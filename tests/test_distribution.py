"""Broadcast distribution plane (DESIGN.md §11): capability-tiered multicast
encodes each broadcast once per TIER (not per client) with exact per-tier
billing, catch-up ranges serve from the encoded-delta cache with zero new
origin encodes, and the tier table + cache index persist through checkpoint
format 5 (formats 1-4 still load, parking the pre-tiering download total
under a legacy breakdown key)."""
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core.codec import ALL_CAPABILITIES, CodecConfig, CodecSpec
from repro.core.compression import CommLedger
from repro.core.sparsify import SparsifyConfig
from repro.data.synthetic import TaskConfig
from repro.fed.distribution import DistributionConfig, EncodedDeltaCache
from repro.fed.endpoints import ServerEndpoint
from repro.fed.protocol import WireProtocol
from repro.fed.strategies import EcoLoRAConfig, FedITPolicy
from repro.fed.trainer import FedConfig, FederatedTrainer

CFG = get_config("llama2-7b").reduced()
TC = TaskConfig(vocab_size=128, seq_len=16, n_samples=256, seed=0)

# the downlink stack with the deepest fallback chain: int8+ans degrades to
# int8 degrades to the mandatory fp16 default — three tiers
ANS_DOWN = CodecConfig(downlink=CodecSpec(quantize="int8", entropy="ans"))
FULL_CAPS = sorted(ALL_CAPABILITIES)
NO_ANS = [c for c in FULL_CAPS if c != "ans"]
BASELINE = [c for c in FULL_CAPS if c not in ("ans", "int8")]

REF_TAG = "topk[adaptive]+int8+golomb+ans"
INT8_TAG = "topk[adaptive]+int8+golomb"
FP16_TAG = "topk[adaptive]+fp16+golomb"


def _server(n_clients=6, codec=ANS_DOWN, distribution=None):
    spec = [("x/a", (64,), np.float32), ("x/b", (64,), np.float32)]
    proto = WireProtocol(spec, eco=EcoLoRAConfig(n_segments=1), codec=codec)
    return ServerEndpoint(FedITPolicy(), proto, n_clients=n_clients,
                          distribution=distribution)


def _drive(srv, rounds, caps, rng, sync=None):
    """Drive ``rounds`` broadcasts; sync the clients listed in ``sync`` (or
    everyone) each round with their capability lists. Returns per-client
    DownloadMsg history."""
    history = {cid: [] for cid in caps}
    for t in range(rounds):
        srv.global_vec = srv.global_vec + rng.standard_normal(
            srv.protocol.size).astype(np.float32)
        srv.begin_round(t)
        for cid in (sync(t) if sync is not None else sorted(caps)):
            history[cid].append(srv.sync_client(cid, t,
                                                capabilities=caps[cid]))
    return history


# ---------------------------------------------------------------------------
# the tentpole pin: encode once per TIER, bill exactly per client
# ---------------------------------------------------------------------------

def test_encode_once_per_tier_with_exact_billing():
    """6 clients in 3 capability tiers: after negotiation every broadcast
    runs exactly THREE pipeline encodes (one per tier, however many clients
    subscribe), each client's per-round bill equals its OWN tier's step
    bytes, and the ledger breakdown sums per tier."""
    srv = _server(6)
    plane = srv.distribution
    caps = {0: FULL_CAPS, 1: FULL_CAPS, 2: NO_ANS, 3: NO_ANS,
            4: BASELINE, 5: BASELINE}
    rounds = 5
    hist = _drive(srv, rounds, caps, np.random.default_rng(0))

    assert plane.plan() == {REF_TAG: [0, 1], INT8_TAG: [2, 3],
                            FP16_TAG: [4, 5]}
    # broadcast 1 predates negotiation (reference encode only); every later
    # broadcast is exactly one encode per tier — NOT one per client
    assert plane.last_broadcast_encodes == 3
    assert plane.total_encodes == 1 + 3 * (rounds - 1)

    # per-client bills are their tier's encoded step bytes: clients sharing
    # a tier bill identically, different tiers bill differently
    for cid, tag in [(0, REF_TAG), (2, INT8_TAG), (4, FP16_TAG)]:
        assert all(dl.tier == tag for dl in hist[cid])
        twin = {0: 1, 2: 3, 4: 5}[cid]
        assert [dl.wire_bytes for dl in hist[cid]] \
            == [dl.wire_bytes for dl in hist[twin]]
    by_round = {tag: [dl.wire_bytes for dl in hist[cid]]
                for cid, tag in [(0, REF_TAG), (2, INT8_TAG),
                                 (4, FP16_TAG)]}
    # rounds >= 1 bill the tier's OWN encode of the same delta: the fp16
    # tier costs more wire than the int8 tiers
    assert sum(by_round[FP16_TAG][1:]) > sum(by_round[INT8_TAG][1:])

    # the ledger's downlink breakdown: per-tier sums, exactly the total
    led = srv.ledger
    assert set(led.download_by_codec) == {REF_TAG, INT8_TAG, FP16_TAG}
    for cid, tag in [(0, REF_TAG), (2, INT8_TAG), (4, FP16_TAG)]:
        want = 2 * sum(dl.wire_bytes for dl in hist[cid])   # two clients
        # round 0 billed before negotiation -> under the reference tier
        if tag != REF_TAG:
            want -= 2 * by_round[tag][0]
        assert led.download_by_codec[tag] >= want
    assert sum(led.download_by_codec.values()) == led.download_bytes

    # every tier's cumulative equals the sum of its cached step entries
    for tag in (INT8_TAG, FP16_TAG):
        steps = [plane.cache.get((v, v + 1, tag)).stats
                 for v in range(1, rounds)]
        np.testing.assert_array_equal(plane._cum[tag],
                                      np.sum(steps, axis=0))


def test_single_tier_default_is_pure_bookkeeping():
    """Under the default downlink config everyone resolves to the one
    reference tier: a capability-advertising population bills BITWISE what
    a legacy (no-capabilities) population bills, there is exactly one
    encode per broadcast, and the breakdown is a single entry."""
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    srv_a = _server(4, codec=None)
    srv_b = _server(4, codec=None)
    caps_none = {cid: None for cid in range(4)}
    caps_full = {cid: FULL_CAPS for cid in range(4)}
    hist_a = _drive(srv_a, 4, caps_none, rng_a)
    hist_b = _drive(srv_b, 4, caps_full, rng_b)

    for cid in range(4):
        assert [(d.wire_bytes, d.param_count) for d in hist_a[cid]] \
            == [(d.wire_bytes, d.param_count) for d in hist_b[cid]]
    la, lb = srv_a.ledger, srv_b.ledger
    assert (la.download_bytes, la.download_params) \
        == (lb.download_bytes, lb.download_params)
    for srv in (srv_a, srv_b):
        plane = srv.distribution
        assert plane.last_broadcast_encodes == 1
        assert plane.total_encodes == 4
        assert not plane.billing            # nobody off the reference tier
        ref = plane.ref_tag
        assert srv.ledger.download_by_codec == {ref: srv.ledger.download_bytes}
    np.testing.assert_array_equal(srv_a._client_cum, srv_b._client_cum)


# ---------------------------------------------------------------------------
# catch-up serving from the encoded-delta cache
# ---------------------------------------------------------------------------

def test_idle_client_catchup_is_cache_hit_with_zero_encodes():
    """A client away for many broadcasts returns: its catch-up range is
    composed from the cached per-broadcast step entries — a HIT, zero new
    origin encodes — and the coalesced range is inserted back so the NEXT
    client over the same gap hits the exact key."""
    srv = _server(3)
    plane = srv.distribution
    caps = {0: FULL_CAPS, 1: BASELINE, 2: BASELINE}
    rng = np.random.default_rng(1)
    # round 0: everyone syncs (negotiates); rounds 1-6: only client 0
    _drive(srv, 7, caps, rng, sync=lambda t: [0, 1, 2] if t == 0 else [0])

    tag = plane.tier_tag(1)
    assert tag == FP16_TAG
    enc0, hits0, len0 = plane.total_encodes, plane.cache.hits, \
        len(plane.cache)
    dl = srv.sync_client(1, 6, capabilities=caps[1])
    assert dl.n_missed == 6
    assert plane.total_encodes == enc0, "catch-up must not re-encode"
    assert plane.cache.hits == hits0 + 1
    assert (1, 7, tag) in plane.cache       # coalesced range inserted back
    assert len(plane.cache) == len0 + 1
    # the bill is exactly the tier's cached step bytes over the gap
    want = sum(plane.cache.get((v, v + 1, tag)).wire_bytes
               for v in range(1, 7))
    assert dl.wire_bytes == want
    assert dl.tier == tag

    # second straggler over the SAME gap: exact-key hit, no index growth
    hits1, len1 = plane.cache.hits, len(plane.cache)
    dl2 = srv.sync_client(2, 6, capabilities=caps[2])
    assert dl2.wire_bytes == dl.wire_bytes
    assert plane.cache.hits == hits1 + 1
    assert len(plane.cache) == len1
    assert plane.cache.misses == 0


def test_evicted_range_is_a_miss_but_bills_exactly():
    """A cache too small to hold the gap's steps records a MISS (origin
    refill on a real edge) — but the prefix-sum bill is exact regardless,
    and still no re-encode happens server-side."""
    srv = _server(2, distribution=DistributionConfig(cache_budget_bytes=64))
    plane = srv.distribution
    caps = {0: FULL_CAPS, 1: BASELINE}
    rng = np.random.default_rng(2)
    _drive(srv, 5, caps, rng, sync=lambda t: [0, 1] if t == 0 else [0])

    assert len(plane.cache) == 0            # nothing fit the 64-byte budget
    before = srv.ledger.download_bytes
    enc0, misses0 = plane.total_encodes, plane.cache.misses
    dl = srv.sync_client(1, 4, capabilities=caps[1])
    assert dl.n_missed == 4
    assert plane.cache.misses == misses0 + 1
    assert plane.total_encodes == enc0
    # exact billing: the tier cumulative delta, independent of cache state
    assert srv.ledger.download_bytes - before == dl.wire_bytes
    assert dl.wire_bytes > 0


# ---------------------------------------------------------------------------
# the cache itself
# ---------------------------------------------------------------------------

def test_cache_lru_eviction_stays_within_budget():
    cache = EncodedDeltaCache(budget_bytes=100)
    assert cache.put((0, 1, "t"), (1, 40, 2))
    assert cache.put((1, 2, "t"), (1, 40, 2))
    assert cache.nbytes() == 80
    cache.get((0, 1, "t"))                   # bump: (1,2) is now LRU
    assert cache.put((2, 3, "t"), (1, 40, 2))
    assert (1, 2, "t") not in cache          # the LRU entry went
    assert (0, 1, "t") in cache and (2, 3, "t") in cache
    assert cache.nbytes() == 80 <= cache.budget
    assert cache.evictions == 1


def test_cache_rejects_oversized_entries():
    cache = EncodedDeltaCache(budget_bytes=100)
    assert not cache.put((0, 5, "t"), (9, 101, 9))
    assert len(cache) == 0 and cache.nbytes() == 0
    # replacing a key re-accounts its bytes instead of double-charging
    assert cache.put((0, 1, "t"), (1, 60, 2))
    assert cache.put((0, 1, "t"), (1, 90, 2))
    assert len(cache) == 1 and cache.nbytes() == 90


def test_cache_state_round_trips_index_only():
    cache = EncodedDeltaCache(budget_bytes=1000)
    cache.put((0, 1, "t"), (1, 10, 2), packets=["payload"])
    cache.put((1, 2, "u"), (3, 20, 4))
    cache.hits, cache.misses, cache.evictions = 5, 2, 1
    st = cache.state()
    fresh = EncodedDeltaCache(budget_bytes=1000)
    fresh.load_state(st)
    assert len(fresh) == 2 and fresh.nbytes() == 30
    assert (fresh.hits, fresh.misses, fresh.evictions) == (5, 2, 1)
    entry = fresh.get((0, 1, "t"))
    np.testing.assert_array_equal(entry.stats, [1, 10, 2])
    assert entry.packets is None, "payloads are memory-only"


def test_distribution_config_validates():
    with pytest.raises(ValueError, match="cache_budget_bytes"):
        DistributionConfig(cache_budget_bytes=0).validate()


# ---------------------------------------------------------------------------
# ledger breakdown (satellite: download_by_codec mirrors upload_by_codec)
# ---------------------------------------------------------------------------

def test_ledger_download_breakdown_accumulates_per_codec():
    led = CommLedger()
    led.log_download_stats(10, 100, 200, codec="a")
    led.log_download_stats(5, 50, 80, codec="a")
    led.log_download_stats(1, 7, 9, codec="b")
    assert led.download_by_codec == {"a": 150, "b": 7}
    assert led.download_bytes == 157
    # an up-to-date client's zero-byte sync is not a wire event
    led.log_download_stats(0, 0, 0, codec="a")
    assert led.download_by_codec == {"a": 150, "b": 7}
    # legacy callers without attribution change no breakdown
    led.log_download_stats(2, 11, 13)
    assert led.download_by_codec == {"a": 150, "b": 7}
    assert led.download_bytes == 168


# ---------------------------------------------------------------------------
# persistence: checkpoint format 5 (and formats without the plane block)
# ---------------------------------------------------------------------------

def _make_trainer(caps=None, rounds=4):
    fed = FedConfig(method="fedit", n_clients=8, clients_per_round=4,
                    rounds=rounds, local_steps=2, local_batch=4, lr=3e-3,
                    eco=EcoLoRAConfig(n_segments=2,
                                      sparsify=SparsifyConfig()),
                    pretrain_steps=5, engine="batched", codec=ANS_DOWN,
                    client_capabilities=caps)
    return FederatedTrainer(CFG, fed, TC)


def _tier_caps():
    return {cid: list((FULL_CAPS, NO_ANS, BASELINE)[cid % 3])
            for cid in range(8)}


def test_format5_resume_parity_multitier(tmp_path):
    """Save a tiered run mid-way, resume in a fresh trainer: tier table,
    per-tier cumulatives, cache index and the ledger's download breakdown
    all restore, and the finished resumed run matches an uninterrupted one
    bitwise — downlink bytes, breakdown, and global vector."""
    caps = _tier_caps()
    full = _make_trainer(caps=caps)
    full.run()

    first = _make_trainer(caps=caps)
    first.run(rounds=2)
    p = str(tmp_path / "tiered.ckpt")
    ckpt.save_fed_state(p, first)

    resumed = _make_trainer(caps=caps)
    assert ckpt.load_fed_state(p, resumed) == 2
    pa, pb = first.server.distribution, resumed.server.distribution
    assert pb.table == pa.table and len(pb.table) > 0
    assert pb.billing == pa.billing
    assert set(pb._cum) == set(pa._cum)
    for tag in pa._cum:
        np.testing.assert_array_equal(pb._cum[tag], pa._cum[tag])
    assert pb.cache.state() == pa.cache.state()
    assert pb.total_encodes == pa.total_encodes
    assert resumed.server.ledger.download_by_codec \
        == first.server.ledger.download_by_codec
    resumed.run()

    la, lb = full.server.ledger, resumed.server.ledger
    assert la.download_bytes == lb.download_bytes
    assert la.download_by_codec == lb.download_by_codec
    assert la.upload_bytes == lb.upload_bytes
    np.testing.assert_array_equal(full.server.global_vec,
                                  resumed.server.global_vec)


def test_pre_tiering_checkpoint_loads_with_legacy_key(tmp_path):
    """A format-4 checkpoint (no distribution block, no download
    breakdown) still loads: the plane starts fresh and the restored
    download total parks under the legacy breakdown key, keeping the
    sum(download_by_codec) == download_bytes invariant."""
    first = _make_trainer(caps=None)
    first.run(rounds=2)
    p = str(tmp_path / "fmt5.ckpt")
    ckpt.save_fed_state(p, first)

    state = ckpt.load(p)
    assert state["format"] == 5 and state.get("distribution") is not None
    state["format"] = 4
    del state["distribution"]
    del state["ledger"]["download_by_codec"]
    p4 = str(tmp_path / "fmt4.ckpt")
    ckpt.save(p4, state)

    resumed = _make_trainer(caps=None)
    assert ckpt.load_fed_state(p4, resumed) == 2
    led = resumed.server.ledger
    assert led.download_bytes == first.server.ledger.download_bytes
    assert led.download_by_codec \
        == {"legacy(pre-tiering)": led.download_bytes}
    resumed.run()                                 # keeps running fine
    assert sum(led.download_by_codec.values()) == led.download_bytes
