"""Network simulator (ns-3 replacement) behaviour."""
from repro.netsim.network import SCENARIOS, NetworkSimulator


def test_transfer_time_asymmetry():
    sim = NetworkSimulator(SCENARIOS["1/5"])
    up = sim.transfer_time(10**6, up=True)
    down = sim.transfer_time(10**6, up=False)
    assert up > down  # uplink slower (Konecny 2016)
    assert up > 8 / (1e6 * 0.9)  # at least the serialization delay


def test_round_straggler_semantics():
    sim = NetworkSimulator(SCENARIOS["2/10"])
    rt = sim.round(0, [1000, 10_000_000], [1000, 10_000_000], [0.1, 0.1])
    # the big-transfer client defines the round
    assert rt.upload_s > sim.transfer_time(1000, True)
    totals = sim.totals()
    assert totals["total_s"] == rt.total_s


def test_empty_round_zero_timing():
    """All sampled clients dropped out: the round costs only the overhead
    (the old max() over an empty sequence raised)."""
    sim = NetworkSimulator(SCENARIOS["1/5"])
    rt = sim.round(0, [], [], [], overhead_s=0.25)
    assert rt.download_s == rt.compute_s == rt.upload_s == 0.0
    assert rt.total_s == 0.25
    assert sim.totals()["total_s"] == 0.25


def test_worse_network_longer_rounds():
    times = {}
    for name in ("0.2/1", "1/5", "2/10", "5/25"):
        sim = NetworkSimulator(SCENARIOS[name])
        rt = sim.round(0, [5 * 10**6], [5 * 10**6], [1.0])
        times[name] = rt.total_s
    assert times["0.2/1"] > times["1/5"] > times["2/10"] > times["5/25"]
