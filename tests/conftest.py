"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (a separate process) forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
