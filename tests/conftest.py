"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (a separate process) forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platform_name", "cpu")

# CI backend matrix (.github/workflows/ci.yml): ECOLORA_TEST_BACKEND=pallas
# flips the DEFAULT uplink/downlink sparsify backend for every test that
# doesn't pin one, so the whole fast suite also runs through the fused
# Pallas kernels (CPU interpret mode here; real kernels on TPU). Tests that
# pass backend= explicitly — the numpy-vs-pallas parity pins — are
# unaffected, which is what keeps the matrix legs comparable.
_BACKEND = os.environ.get("ECOLORA_TEST_BACKEND")
if _BACKEND:
    if _BACKEND not in ("numpy", "pallas"):
        raise ValueError(
            f"ECOLORA_TEST_BACKEND={_BACKEND!r}: expected numpy or pallas")
    from repro.fed.trainer import FedConfig
    FedConfig.__dataclass_fields__["backend"].default = _BACKEND


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
