"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
family (2 layers, d_model<=512, <=4 experts) runs one forward + one train
step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.optim import adamw

# the heaviest reduced variants — excluded from the fast CI gate
_HEAVY = {"deepseek-v3-671b", "zamba2-1.2b", "llama3.2-1b",
          "llama-3.2-vision-11b", "musicgen-large"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY
               else a for a in ASSIGNED_ARCHS]


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = M.init_params(cfg, rng_key)
    lora = M.init_lora(cfg, jax.random.PRNGKey(1))
    batch = M.make_batch(cfg, 2, 32, jax.random.PRNGKey(2))

    h, aux, _ = M.trunk(params, lora, batch["tokens"], cfg,
                        cond=batch.get("cond"), remat=False)
    assert h.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.isnan(h).any())

    loss, grads = jax.value_and_grad(M.loss_fn)(lora, params, batch, cfg, False)
    assert jnp.isfinite(loss)
    opt = adamw.init_state(lora)
    lora2, _ = adamw.apply_updates(lora, grads, opt, adamw.AdamWConfig(lr=1e-3))
    # at least one LoRA leaf must have moved
    moved = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree_util.tree_leaves(lora), jax.tree_util.tree_leaves(lora2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_serve_decode_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, rng_key)
    lora = M.init_lora(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    shapes = M.cache_shapes(cfg, B, S)
    cache = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s, jnp.float32), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = M.decode_step(params, lora, tok, cache, 3, cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree_util.tree_structure(new_cache) == jax.tree_util.tree_structure(cache)
