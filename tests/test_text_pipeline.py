"""Tokenizer + text loader (the real-text data path)."""
import numpy as np

from repro.data.loader import TextDataset, epoch_batches
from repro.data.tokenizer import PAD, ByteTokenizer


def test_byte_roundtrip():
    tok = ByteTokenizer()
    for s in ("hello world", "françois 🙂", ""):
        assert tok.decode(tok.encode(s, bos=False)) == s


def test_merges_shrink_and_roundtrip():
    corpus = ["the cat sat on the mat"] * 8 + ["the dog sat on the log"] * 8
    tok = ByteTokenizer().train(corpus, num_merges=64)
    plain = ByteTokenizer()
    s = "the cat sat on the log"
    assert len(tok.encode(s)) < len(plain.encode(s))
    assert tok.decode(tok.encode(s, bos=False)) == s
    assert tok.vocab_size > 256 + 4


def test_instruction_batching_masks_prompt():
    tok = ByteTokenizer()
    ds = TextDataset.from_pairs(
        tok, [("what is 2+2?", "four"), ("name a color", "blue")], seq_len=48)
    b = ds.batch(np.array([0, 1]))
    assert b["tokens"].shape == (2, 48)
    assert b["labels"].shape == (2, 48)
    # loss mask covers completion region only, nothing in the prompt
    ids0, plen0 = ds.examples[0]
    assert b["loss_mask"][0, : plen0 - 1].sum() == 0
    assert b["loss_mask"][0].sum() > 0
    # padding positions carry no loss
    assert (b["loss_mask"][0][b["tokens"][0] == PAD][1:] == 0).all()


def test_epoch_batches():
    tok = ByteTokenizer()
    ds = TextDataset.from_pairs(tok, [("q", "a")] * 10, seq_len=16)
    batches = list(epoch_batches(ds, 3, np.random.default_rng(0)))
    assert len(batches) == 3
