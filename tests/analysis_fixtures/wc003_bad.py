"""WC003 violation: constructor call omits a non-defaulted field."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int
    b: int
    c: int = 0


def make():
    return Msg(1)                  # b never passed
