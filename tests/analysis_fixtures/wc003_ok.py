"""WC003 clean twin: every required field bound."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int
    b: int
    c: int = 0


def make():
    return Msg(1, b=2)
