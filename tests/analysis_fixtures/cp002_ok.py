"""CP002 clean twin: the optional key is presence-guarded with .get."""


class Thing:
    def __init__(self):
        self.x = 0

    def state(self):
        return {"x": int(self.x)}

    def load_state(self, st):
        self.x = int(st["x"])
        self.z = int(st.get("z", 0))
