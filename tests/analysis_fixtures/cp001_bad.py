"""CP001 violation: a saved key is never restored."""


class Thing:
    def __init__(self):
        self.x = 0
        self.y = 0

    def state(self):
        return {"x": int(self.x), "y": int(self.y)}

    def load_state(self, st):
        self.x = int(st["x"])      # 'y' silently resets on resume
