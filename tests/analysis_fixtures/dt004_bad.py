"""DT004 violation: float accumulation in dict insertion order."""


def total_cost(costs):
    return sum(costs.values())
