"""CP002 violation: hard read of a key the save path never writes."""


class Thing:
    def __init__(self):
        self.x = 0

    def state(self):
        return {"x": int(self.x)}

    def load_state(self, st):
        self.x = int(st["x"])
        self.z = int(st["z"])      # KeyError on every fresh file
