"""WC004 violation: unpack reads a key pack never writes."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int


def _pack_msg(m):
    return {"a": int(m.a)}


def _unpack_msg(d):
    ghost = d["ghost"]             # never written by _pack_msg
    return Msg(int(d["a"]) + int(ghost))
