"""DT004 clean twin: sorted items pin the accumulation order."""


def total_cost(costs):
    return sum(v for _, v in sorted(costs.items()))
