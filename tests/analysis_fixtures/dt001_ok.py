"""DT001 clean twin: sorted() pins the order."""


def doubled(ids):
    seen = set(ids)
    return [i * 2 for i in sorted(seen)]
