"""DT002 clean twin: the simulated event clock is threaded in."""


def bill_round(ledger, sim_clock_s):
    ledger["t"] = float(sim_clock_s)
    return ledger
