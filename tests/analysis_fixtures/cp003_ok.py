"""CP003 clean twin: the gate cites a written format number."""


def save_thing(path, thing):
    return {"format": 2, "x": int(thing.x)}


def load_thing(state, thing):
    fmt = int(state.get("format", 1))
    if fmt >= 2:
        thing.x = int(state["x"])
