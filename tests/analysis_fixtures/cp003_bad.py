"""CP003 violation: a format gate cites a format no save path writes."""


def save_thing(path, thing):
    return {"format": 2, "x": int(thing.x)}


def load_thing(state, thing):
    fmt = int(state.get("format", 1))
    if fmt >= 7:                   # format 7 does not exist
        thing.x = int(state["x"])
