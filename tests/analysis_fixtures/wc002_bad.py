"""WC002 violation: pack writes a key unpack never reads."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int


def _pack_msg(m):
    return {"a": int(m.a), "extra": 1}     # 'extra' is dead on arrival


def _unpack_msg(d):
    return Msg(int(d["a"]))
