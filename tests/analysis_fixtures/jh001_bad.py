"""JH001 violations: host syncs inside a jitted function."""
import functools

import jax
import numpy as np


@functools.partial(jax.jit, static_argnames=("flag",))
def f(x, flag):
    y = x * 2
    if y.sum() > 0:                # Python branch on a traced value
        return float(y.sum())      # float() concretises the tracer
    return np.asarray(y)           # numpy pulls the array to host
