"""WC001 clean twin: every field travels."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int
    b: int


def _pack_msg(m):
    return {"a": int(m.a), "b": int(m.b)}


def _unpack_msg(d):
    return Msg(int(d["a"]), int(d["b"]))
