"""DT003 violation: global unseeded randomness."""
import random


def pick(xs):
    return random.choice(xs)
