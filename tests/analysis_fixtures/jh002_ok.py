"""JH002 clean twin: hashable statics, jit hoisted out of loops."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("opts",))
def g(x, opts=()):
    return x


def caller(x):
    return g(x, opts=(1, 2))


def build_all(fns, x):
    jitted = [jax.jit(fn) for fn in fns]
    return [fn(x) for fn in jitted]
