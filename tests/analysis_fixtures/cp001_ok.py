"""CP001 clean twin: every saved key round-trips."""


class Thing:
    def __init__(self):
        self.x = 0
        self.y = 0

    def state(self):
        return {"x": int(self.x), "y": int(self.y)}

    def load_state(self, st):
        self.x = int(st["x"])
        self.y = int(st["y"])
