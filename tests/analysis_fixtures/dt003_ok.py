"""DT003 clean twin: an explicit Generator seeded from the run config."""
import numpy as np


def pick(xs, seed):
    rng = np.random.default_rng(seed)
    return xs[int(rng.integers(len(xs)))]
