"""DT001 violation: order-sensitive iteration over a set."""


def doubled(ids):
    seen = set(ids)
    return [i * 2 for i in seen]   # order varies across runs
