"""JH002 violations: retrace hazards."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("opts",))
def g(x, opts=()):
    return x


@functools.partial(jax.jit, static_argnames=("missing",))
def h(x):                          # 'missing' is not a parameter
    return x


def caller(x):
    return g(x, opts=[1, 2])       # list literal: unhashable static


def build_all(fns, x):
    outs = []
    for fn in fns:
        outs.append(jax.jit(fn)(x))    # jit built inside the loop
    return outs
