"""WC004 clean twin: unpack reads only written keys."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int


def _pack_msg(m):
    return {"a": int(m.a)}


def _unpack_msg(d):
    return Msg(int(d["a"]))
