"""JH001 clean twin: shape-based statics, device-side math only."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flag",))
def f(x, flag):
    n = x.shape[0]                 # shapes are static: branching is fine
    if flag and n > 1:
        return x * 2
    return jnp.where(x > 0, x, jnp.sum(x))
