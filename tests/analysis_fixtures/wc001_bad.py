"""WC001 violation: the pack path drops a message field."""
from dataclasses import dataclass


@dataclass
class Msg:
    a: int
    b: int


def _pack_msg(m):
    return {"a": int(m.a)}        # m.b never serialized


def _unpack_msg(d):
    return Msg(int(d["a"]), 0)
