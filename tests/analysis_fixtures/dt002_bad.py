"""DT002 violation: wall-clock read in billed state."""
import time


def bill_round(ledger):
    ledger["t"] = time.perf_counter()
    return ledger
