"""SimTransport scenarios: heterogeneous links, dropout, buffered-async
M-of-K aggregation, and message-level event timestamps."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparsify import SparsifyConfig
from repro.data.synthetic import TaskConfig
from repro.fed.strategies import EcoLoRAConfig
from repro.fed.trainer import FedConfig, FederatedTrainer
from repro.fed.transport import InMemoryTransport, SimTransport
from repro.netsim.network import SCENARIOS, NetworkSimulator

CFG = get_config("llama2-7b").reduced()
TC = TaskConfig(vocab_size=128, seq_len=16, n_samples=256, seed=0)


def _run(transport, rounds=3, **kw):
    base = dict(method="fedit", n_clients=8, clients_per_round=4,
                rounds=rounds, local_steps=2, local_batch=4, lr=3e-3,
                eco=EcoLoRAConfig(n_segments=2, sparsify=SparsifyConfig()),
                pretrain_steps=5, compute_model_s=0.05)
    base.update(kw)
    tr = FederatedTrainer(CFG, FedConfig(**base), TC, transport=transport)
    tr.run()
    return tr


def test_sim_sync_transport_is_protocol_transparent():
    """A lossless sync SimTransport only adds timing — the protocol state
    and ledger are bitwise those of InMemoryTransport."""
    a = _run(InMemoryTransport())
    b = _run(SimTransport(SCENARIOS["1/5"]))
    np.testing.assert_array_equal(a.server.global_vec, b.server.global_vec)
    assert a.server.ledger.total_bytes == b.server.ledger.total_bytes
    # and it produced a timed round per federation round
    assert len(b.transport.timeline) == len(b.logs)
    assert b.transport.totals()["communication_s"] > 0


def test_message_events_timestamped():
    tr = _run(SimTransport(SCENARIOS["1/5"]), rounds=2)
    ev = tr.transport.events
    kinds = {e.kind for e in ev}
    assert kinds == {"broadcast", "download", "upload"}
    assert all(e.t_end >= e.t_start >= 0.0 for e in ev)
    # the clock advances monotonically across rounds
    starts = [e.t_start for e in ev if e.kind == "broadcast"]
    assert starts == sorted(starts) and starts[1] > starts[0]


def test_dropout_clients_skip_round():
    full = _run(SimTransport(SCENARIOS["1/5"], seed=3))
    lossy = _run(SimTransport(SCENARIOS["1/5"], dropout=0.5, seed=3))
    assert lossy.transport.dropped, "expected at least one dropped client"
    n_up_full = sum(1 for e in full.transport.events if e.kind == "upload")
    n_up_lossy = sum(1 for e in lossy.transport.events if e.kind == "upload")
    assert n_up_lossy < n_up_full
    assert lossy.server.ledger.upload_bytes < full.server.ledger.upload_bytes
    # run still completes every round and keeps a finite model
    assert len(lossy.logs) == 3
    assert np.isfinite(lossy.server.global_vec).all()


def test_dropout_survives_empty_rounds():
    tr = _run(SimTransport(SCENARIOS["1/5"], dropout=0.95, seed=0), rounds=4)
    assert len(tr.logs) == 4
    assert np.isfinite(tr.server.global_vec).all()


def test_buffered_async_m_of_k():
    """buffered_async aggregates after the first M of K uploads; stragglers
    land at the NEXT round's aggregation, and each round is faster than the
    straggler-bound synchronous round."""
    # clients 0-3 on slow links, 4-7 on fast ones: the M-of-K cutoff skips
    # the slow stragglers whenever a fast client is sampled
    het = {i: SCENARIOS["0.2/1"] for i in range(4)}
    sync = _run(SimTransport(SCENARIOS["5/25"], per_client=het, seed=1))
    asy = _run(SimTransport(SCENARIOS["5/25"], per_client=het,
                            round_mode="buffered_async",
                            min_uploads=2, seed=1))
    assert asy.transport.straggler_count() > 0
    # round 1 consumes round-0 stragglers alongside its own on-time uploads
    consumed_r1 = [e for e in asy.transport.events
                   if e.kind == "upload" and e.delivered_round == 1]
    assert any(e.round_t == 0 for e in consumed_r1)
    assert any(e.round_t == 1 for e in consumed_r1)
    # M-of-K cuts the wait for the slowest clients (compare the simulated
    # network+compute legs; overhead_s is measured host walltime and noisy)
    for rt_async, rt_sync in zip(asy.transport.timeline,
                                 sync.transport.timeline):
        assert rt_async.comm_s + rt_async.compute_s \
            <= rt_sync.comm_s + rt_sync.compute_s + 1e-9
    assert (asy.transport.totals()["communication_s"]
            < sync.transport.totals()["communication_s"])
    assert np.isfinite(asy.server.global_vec).all()


def test_heterogeneous_per_client_links():
    slow, fast = SCENARIOS["0.2/1"], SCENARIOS["5/25"]
    sim = NetworkSimulator(fast, per_client={7: slow})
    assert sim.transfer_time(10**6, up=True, cid=7) \
        > sim.transfer_time(10**6, up=True, cid=3)
    # the slow client is the straggler and defines the round
    rt = sim.round(0, [10**5, 10**5], [10**5, 10**5], [0.1, 0.1],
                   client_ids=[3, 7])
    assert abs(rt.upload_s - sim.transfer_time(10**5, True, cid=7)) < 1e-12

    tr = _run(SimTransport(fast, per_client={i: slow for i in range(4)},
                           seed=2))
    # some rounds sample a slow client: their upload leg dominates
    up_times = [rt.upload_s for rt in tr.transport.timeline]
    assert max(up_times) > min(up_times)


def test_flora_stacked_downloads_timed():
    """FLoRA's per-participant stacked-module downlink must reach the
    transport: billed bytes stay byte-identical to InMemoryTransport AND the
    simulated timeline accounts the stacked packets' delivery time."""
    a = _run(InMemoryTransport(), method="flora")
    b = _run(SimTransport(SCENARIOS["1/5"]), method="flora")
    assert a.server.ledger.download_bytes == b.server.ledger.download_bytes
    ev_down = [e for e in b.transport.events if e.kind == "download"]
    k, rounds = b.fed.clients_per_round, len(b.logs)
    # K sync catch-ups per round PLUS K stacked modules per participant
    assert len(ev_down) > k * rounds
    assert all(rt.download_s > 0 for rt in b.transport.timeline)
    # the stacked modules dominate the downlink leg vs a fedit run
    fedit = _run(SimTransport(SCENARIOS["1/5"]))
    assert (sum(rt.download_s for rt in b.transport.timeline)
            > sum(rt.download_s for rt in fedit.transport.timeline))


def test_sim_transport_validation():
    with pytest.raises(ValueError, match="round_mode"):
        SimTransport(round_mode="fire_and_forget")
    with pytest.raises(ValueError, match="min_uploads"):
        SimTransport(round_mode="buffered_async")
    with pytest.raises(ValueError, match="min_uploads"):
        SimTransport(round_mode="buffered_async", min_uploads=-1)
    with pytest.raises(ValueError, match="dropout"):
        SimTransport(dropout=1.5)


def test_async_rejected_for_flora():
    with pytest.raises(ValueError, match="flora"):
        FederatedTrainer(
            CFG, FedConfig(method="flora", n_clients=8, clients_per_round=4,
                           rounds=1, pretrain_steps=0),
            TC, transport=SimTransport(round_mode="buffered_async",
                                       min_uploads=2))


# ---------------------------------------------------------------------------
# RoundClosePolicy edge cases on the EVENT clock (the wall-clock mirror of
# these lives in tests/test_wire.py on SocketTransport + ManualClock)
# ---------------------------------------------------------------------------

def _fake_upload(cid, wire_bytes=1000):
    from types import SimpleNamespace
    return SimpleNamespace(client_id=cid,
                           packet=SimpleNamespace(wire_bytes=wire_bytes))


def test_event_clock_arrival_exactly_at_deadline_is_on_time():
    from repro.fed.transport import RoundClosePolicy
    tp = SimTransport(SCENARIOS["1/5"])
    t_up = tp.sim.transfer_time(1000, up=True, cid=0)
    # arrival total is compute + uplink (no recorded downlink this round):
    # a deadline EQUAL to it keeps the upload on time (<=, not <)
    policy = RoundClosePolicy(deadline_s=1.0 + t_up)
    out = tp.dispatch_uploads(0, [_fake_upload(0)], [1.0], policy=policy)
    assert [m.client_id for m in out] == [0]
    assert tp.inflight() == []
    tp.finish_round(0)
    # one representable tick tighter and the same arrival is late
    late_policy = RoundClosePolicy(
        deadline_s=np.nextafter(1.0 + t_up, 0.0))
    out = tp.dispatch_uploads(1, [_fake_upload(0)], [1.0],
                              policy=late_policy)
    assert out == []
    assert [m.client_id for m in tp.inflight()] == [0]


def test_event_clock_min_uploads_larger_than_member_count():
    from repro.fed.transport import RoundClosePolicy
    tp = SimTransport(SCENARIOS["1/5"])
    msgs = [_fake_upload(c) for c in range(3)]
    out = tp.dispatch_uploads(0, msgs, [0.1, 0.2, 0.3],
                              policy=RoundClosePolicy(min_uploads=10))
    # an unreachable count never blocks the round: everyone who arrived is
    # consumed and nothing is left in flight
    assert sorted(m.client_id for m in out) == [0, 1, 2]
    assert tp.inflight() == []


def test_event_clock_deadline_close_with_zero_arrivals():
    from repro.fed.transport import RoundClosePolicy
    tp = SimTransport(SCENARIOS["1/5"])
    policy = RoundClosePolicy(deadline_s=0.5)
    # nothing was ever sent: the round closes empty and costs nothing
    assert tp.dispatch_uploads(0, [], [], policy=policy) == []
    assert tp._round_total == 0.0
    tp.finish_round(0)
    # everything sent misses the deadline: still closes empty, but the
    # round lasted until its deadline on the event clock
    out = tp.dispatch_uploads(1, [_fake_upload(0), _fake_upload(1)],
                              [5.0, 6.0], policy=policy)
    assert out == []
    assert sorted(m.client_id for m in tp.inflight()) == [0, 1]
    assert tp._round_total == 0.5
