"""End-to-end federated behaviour: EcoLoRA reduces traffic at parity-level
accuracy; FFA-LoRA freezes A; schedules cover segments."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparsify import SparsifyConfig
from repro.data.synthetic import TaskConfig
from repro.fed.strategies import EcoLoRAConfig
from repro.fed.trainer import FedConfig, FederatedTrainer

CFG = get_config("llama2-7b").reduced()
TC = TaskConfig(vocab_size=128, seq_len=16, n_samples=256, seed=0)


def _run(method, eco, rounds=3, **kw):
    fed = FedConfig(method=method, n_clients=10, clients_per_round=4,
                    rounds=rounds, local_steps=2, local_batch=4, lr=3e-3,
                    eco=eco, pretrain_steps=20, **kw)
    tr = FederatedTrainer(CFG, fed, TC)
    tr.run()
    return tr


@pytest.mark.slow
def test_ecolora_reduces_upload():
    base = _run("fedit", None)
    eco = _run("fedit", EcoLoRAConfig(n_segments=2))
    led_b, led_e = base.server.ledger, eco.server.ledger
    assert led_e.upload_bytes < 0.7 * led_b.upload_bytes
    assert led_e.upload_params < 0.7 * led_b.upload_params


@pytest.mark.slow
def test_ffa_freezes_a():
    tr = _run("ffa_lora", None)
    # protocol vector only covers /b leaves
    assert all(p.endswith("/b") for p, _, _ in tr.spec)
    # A leaves unchanged from init in trained clients
    import jax
    lora0 = tr.lora0
    start = tr.clients.client_start(0, 0, tr.client_views[0])
    lora_t = tr._vec_to_lora(start)
    for (p0, l0), (p1, l1) in zip(
            jax.tree_util.tree_leaves_with_path(lora0),
            jax.tree_util.tree_leaves_with_path(lora_t)):
        last = str(p0[-1])
        if "'a'" in last or last.endswith("a"):
            np.testing.assert_allclose(np.asarray(l0, np.float32),
                                       np.asarray(l1, np.float32))


@pytest.mark.slow
def test_metric_not_degraded_by_eco():
    base = _run("fedit", None, rounds=4)
    eco = _run("fedit", EcoLoRAConfig(
        n_segments=2, sparsify=SparsifyConfig(k_max=0.95, k_min_a=0.6,
                                              k_min_b=0.5)), rounds=4)
    m_b = base.logs[-1].metric
    m_e = eco.logs[-1].metric
    assert m_e >= m_b - 0.05  # parity within noise (paper Tables 1/2)


def test_dirichlet_noniid_partition():
    from repro.data.partition import dirichlet_partition, partition_stats
    from repro.data.synthetic import InstructionTask
    task = InstructionTask(TC)
    parts = dirichlet_partition(task.categories, 10, alpha=0.5, seed=0)
    st = partition_stats(parts, task.categories)
    assert st["n_clients"] == 10 and st["min"] >= 2
    covered = np.unique(np.concatenate(parts))
    assert covered.size >= 0.95 * TC.n_samples  # nearly all samples assigned
