"""Client samplers, server endpoint message path, quantization baseline."""
import numpy as np

from repro.core.quantize import QuantConfig, dequantize, quantization_error, quantize, wire_bytes
from repro.fed.sampler import make_sampler


def test_uniform_sampler_no_replacement():
    s = make_sampler("uniform", 100, 10)
    got = s.sample(0)
    assert got.size == 10 and np.unique(got).size == 10


def test_weighted_sampler_prefers_large_clients():
    w = np.ones(50); w[:5] = 100.0
    s = make_sampler("weighted", 50, 5, weights=w)
    hits = sum(int((s.sample(t) < 5).sum()) for t in range(50))
    assert hits > 100  # heavy clients dominate


def test_availability_sampler():
    avail = np.zeros(20); avail[:4] = 1.0
    s = make_sampler("availability", 20, 8, availability=avail)
    got = s.sample(0)
    assert (got < 4).all()
    # short round: fewer online clients than the per-round quota
    assert got.size <= 4


def test_make_sampler_unknown_kind_raises_value_error():
    import pytest
    with pytest.raises(ValueError, match="uniform"):
        make_sampler("round_robin", 10, 2)


def test_sampler_draws_derive_from_seed_and_round():
    """Per-round draws are (seed, round_t) functions with no stream state:
    two sampler instances agree round-by-round regardless of call history —
    the property that lets a resumed run replay the participant schedule."""
    a = make_sampler("uniform", 100, 10, seed=3)
    b = make_sampler("uniform", 100, 10, seed=3)
    for t in (5, 1, 7):                   # out of order, interleaved
        np.testing.assert_array_equal(a.sample(t), b.sample(t))
    np.testing.assert_array_equal(a.sample(2), a.sample(2))  # replayable
    assert not np.array_equal(make_sampler("uniform", 100, 10, seed=4).sample(5),
                              a.sample(5))


def test_coverage_monitor_warns_on_sustained_starvation():
    """AvailabilitySampler segment-coverage guard: sustained low
    availability that starves a round-robin segment (violating the paper's
    Ns <= Nt requirement) warns ONCE per episode and re-arms on recovery."""
    import pytest
    from repro.fed.sampler import SegmentCoverageMonitor

    mon = SegmentCoverageMonitor(n_segments=2, starve_after=3)
    # client 0 alone covers segment t % 2 each round: alternation keeps
    # both segments' gaps below the threshold -> healthy, no warning
    for t in range(6):
        assert mon.observe(t, [0]) == []

    mon = SegmentCoverageMonitor(n_segments=2, starve_after=3)
    # availability collapse: nobody participates for several rounds
    assert mon.observe(0, [0, 1]) == []
    with pytest.warns(RuntimeWarning, match="Ns <= Nt"):
        for t in range(1, 5):
            starved = mon.observe(t, [])
    assert starved == [0, 1]
    # the episode warned exactly once per segment: continuing the outage
    # emits nothing new...
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert mon.observe(5, []) == [0, 1]
    # ...but recovery re-arms the guard for the next episode
    assert mon.observe(6, [0, 1]) == []
    with pytest.warns(RuntimeWarning):
        for t in range(7, 11):
            mon.observe(t, [])


def test_trainer_warns_when_availability_starves_segments():
    """End-to-end: an availability profile near zero produces empty rounds
    and the trainer's coverage guard surfaces the starvation."""
    import pytest
    from repro.configs import get_config
    from repro.data.synthetic import TaskConfig
    from repro.fed.strategies import EcoLoRAConfig
    from repro.fed.trainer import FedConfig, FederatedTrainer

    cfg = get_config("llama2-7b").reduced()
    tc = TaskConfig(vocab_size=128, seq_len=16, n_samples=64, seed=0)
    fed = FedConfig(n_clients=6, clients_per_round=2, rounds=7,
                    local_steps=1, local_batch=2,
                    eco=EcoLoRAConfig(n_segments=2), pretrain_steps=0,
                    sampler="availability",
                    sampler_kw={"availability": [0.0] * 6})
    tr = FederatedTrainer(cfg, fed, tc)
    with pytest.warns(RuntimeWarning, match="segment"):
        tr.run()


def test_quantize_roundtrip_error_decreases_with_bits():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(10_000).astype(np.float32)
    errs = [quantization_error(x, QuantConfig(bits=b)) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]
    codes, scales = quantize(x, QuantConfig(bits=8), rng)
    xq = dequantize(codes, scales, QuantConfig(bits=8))
    assert np.abs(xq - x).max() < 0.1
    assert wire_bytes(10_000, QuantConfig(bits=4)) < wire_bytes(10_000, QuantConfig(bits=8))


def test_server_endpoint_round():
    """The unified endpoint replaces the old Server facade: one round over
    the message API aggregates uploads AND bills the per-client broadcast
    catch-up the facade used to skip."""
    import jax.numpy as jnp
    from repro.core.segments import segment_bounds, segment_id, tree_spec
    from repro.fed.endpoints import ServerEndpoint
    from repro.fed.protocol import UploadMsg, WireProtocol
    from repro.fed.strategies import EcoLoRAConfig, make_policy

    tree = {"l": {"a": jnp.zeros((40,)), "b": jnp.zeros((40,))}}
    proto = WireProtocol(tree_spec(tree), eco=EcoLoRAConfig(n_segments=2))
    srv = ServerEndpoint(make_policy("fedit"), proto, n_clients=4)
    bc = srv.begin_round(0)
    assert bc.segment_schedule == 2
    # two clients upload complementary segments through the message path
    up_comps = proto.make_uplink_compressors(2)
    for cid in (0, 1):
        dl = srv.sync_client(cid, 0)       # facade bug: this was never billed
        seg = segment_id(cid, 0, 2)
        s, e = segment_bounds(80, 2)[seg]
        vec = np.zeros(80, np.float32); vec[s:e] = cid + 1.0
        pkt = up_comps[cid].compress(vec[s:e] - dl.view[s:e], 0, slice_=(s, e))
        srv.receive(UploadMsg(cid, 0, pkt, 10, 1.0))
    srv.end_round(0)
    assert np.abs(srv.global_vec).sum() > 0
    # downloads were billed (the old Server facade left these at 0)
    assert srv.ledger.download_bytes > 0
    assert srv.ledger.upload_bytes > 0
