"""Round-robin segment sharing (§3.3): properties via hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.segments import (SegmentUpdate, aggregate_segments, extract_segment,
                                 segment_bounds, segment_id, segments_covered)


@given(st.integers(1, 2000), st.integers(1, 16))
def test_segment_bounds_partition(total, ns):
    ns = min(ns, total)
    b = segment_bounds(total, ns)
    assert b[0][0] == 0 and b[-1][1] == total
    for (s0, e0), (s1, e1) in zip(b, b[1:]):
        assert e0 == s1 and e0 > s0
    # equal sizes except the last
    sizes = {e - s for s, e in b[:-1]}
    assert len(sizes) <= 1


@given(st.integers(0, 500), st.integers(0, 500), st.integers(1, 32))
def test_schedule_is_round_robin(cid, t, ns):
    assert segment_id(cid, t, ns) == (cid + t) % ns
    # over ns consecutive rounds a client covers every segment
    segs = {segment_id(cid, t + i, ns) for i in range(ns)}
    assert segs == set(range(ns))


@given(st.integers(2, 40), st.integers(1, 10), st.integers(0, 100))
def test_coverage_when_enough_clients(n_clients, ns, t):
    ns = min(ns, n_clients)
    # paper requirement Ns <= Nt guarantees full coverage with CONSECUTIVE ids
    assert segments_covered(list(range(n_clients)), t, ns)


@settings(deadline=None)
@given(st.integers(5, 50), st.integers(1, 5), st.integers(0, 20))
def test_aggregation_weighted_mean(size, ns, t):
    rng = np.random.default_rng(0)
    ns = min(ns, 3)
    global_vec = rng.normal(size=size).astype(np.float32)
    ups = []
    for cid in range(5):
        seg = segment_id(cid, t, ns)
        vals = extract_segment(np.full(size, cid + 1.0, np.float32), seg, ns)
        ups.append(SegmentUpdate(cid, t, seg, vals, 10 * (cid + 1), 0.0))
    out = aggregate_segments(ups, global_vec, ns)
    bounds = segment_bounds(size, ns)
    for seg, (s, e) in enumerate(bounds):
        contributors = [(u.client_id, u.num_samples) for u in ups if u.seg_id == seg]
        if not contributors:
            assert np.allclose(out[s:e], global_vec[s:e])
        else:
            w = np.array([n for _, n in contributors], float)
            expect = sum((c + 1.0) * wi for (c, _), wi in zip(contributors, w / w.sum()))
            assert np.allclose(out[s:e], expect, atol=1e-5)
